"""Fused RMI lookup Pallas kernels: stage-0 MLP + leaf FMA + bounded
search, optionally merged with the delta-buffer prefix search.

This is the paper's hot spot (§2.1's back-of-envelope: the model must
beat ~50 cycles/B-Tree-node) moved to where the paper says it belongs —
an ML accelerator.  Two kernels share one body:

``rmi_lookup_pallas`` — the read-only §3 lookup.  One invocation
performs, for a tile of queries entirely inside VMEM:

  1. stage-0 MLP (dense VPU/MXU math),
  2. leaf-model selection (vector gather from the SoA leaf arrays),
  3. leaf FMA -> position + error window,
  4. fixed-trip-count branchless binary search over the sorted keys.

``rmi_merged_lookup_pallas`` — the writable-index hot path (§3.3).
Steps 1-4 plus, still inside the same kernel invocation:

  5. fixed-trip branchless lower bound over the fused delta key array
     (staged inserts and tombstones, +inf-padded to a power of two),
  6. one prefix-weight gather: ``merged = base_lb + prefix[delta_lb]``.

Emitting ``(base_lb, merged_rank)`` from one ``pallas_call`` removes
the second XLA dispatch and the HBM round-trip for the base lower
bound that the two-dispatch merged lookup pays — exactly the overhead
"Benchmarking Learned Indexes" shows erasing learned-index wins.

VMEM budget (v5e ≈ 16 MiB/core): leaf SoA (M ≤ 200k: 4 arrays × 800 KB
= 3.2 MB) + sorted keys (N ≤ 2M f32 = 8 MB) + delta (≤ 64k entries:
512 KB) + query tile.  At pod scale the sorted array is sharded over
chips (≈ 780K keys/chip for the paper's 200M on 256 chips), so the
whole merged lookup is VMEM-resident — the TPU answer to the paper's
"B-Trees are cache-efficient" objection.

Dynamic gathers from VMEM (`jnp.take`) lower to Mosaic vector gathers;
we validate in interpret mode on CPU (the container has no TPU) —
``interpret=None`` auto-selects interpret mode off-TPU.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _search_steps(max_window: int) -> int:
    return max(1, int(math.ceil(math.log2(max(2, max_window + 1)))) + 1)


def default_interpret() -> bool:
    """Pallas kernels compile via Mosaic only on TPU; everywhere else
    (this CPU container, GPU hosts) they run in interpret mode."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _base_lower_bound(
    q: jnp.ndarray,
    params,                      # flat (w0, b0, w1, b1, ...) values
    leaf_w: jnp.ndarray,
    leaf_b: jnp.ndarray,
    err_lo: jnp.ndarray,
    err_hi: jnp.ndarray,
    keys: jnp.ndarray,
    *,
    n: int,
    num_leaves: int,
    steps: int,
) -> jnp.ndarray:
    """Shared kernel body: stage-0 MLP -> leaf FMA -> first probe ->
    fixed-trip bounded search.  Operates on values (already read from
    refs) so both kernels execute bit-identical arithmetic."""
    nl = len(params) // 2
    # ---- stage 0: tiny MLP, dense math --------------------------------
    h = q[:, None]
    for i in range(nl):
        w, b = params[2 * i], params[2 * i + 1]
        h = h @ w + b[None, :]
        if i < nl - 1:
            h = jnp.maximum(h, 0.0)
    p0 = h[:, 0]

    # ---- leaf select + FMA --------------------------------------------
    leaf = jnp.clip(
        jnp.floor(p0 * (num_leaves / n)).astype(jnp.int32), 0, num_leaves - 1
    )
    slope = jnp.take(leaf_w, leaf)
    inter = jnp.take(leaf_b, leaf)
    pos = jnp.clip(slope * q + inter, 0.0, float(n - 1))
    lo = jnp.clip(
        (pos + jnp.take(err_lo, leaf)).astype(jnp.int32), 0, n
    )
    hi = jnp.clip(
        (pos + jnp.take(err_hi, leaf)).astype(jnp.int32) + 1, 0, n
    )

    # ---- first probe at the prediction (model binary search §3.4) -----
    p0i = jnp.clip(pos.astype(jnp.int32), 0, n - 1)
    kp = jnp.take(keys, p0i)
    right = kp < q
    lo = jnp.where(right, jnp.maximum(lo, p0i + 1), lo)
    hi = jnp.where(right, hi, jnp.minimum(hi, p0i))

    # ---- fixed-trip branchless binary search --------------------------
    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        km = jnp.take(keys, jnp.clip(mid, 0, n - 1))
        r = km < q
        return jnp.where(r, mid + 1, lo), jnp.where(r, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _delta_lower_bound(
    q: jnp.ndarray, dkeys: jnp.ndarray, *, dsteps: int
) -> jnp.ndarray:
    """Full-range branchless lower bound over the padded delta keys
    (+inf pads sort after every finite query)."""
    d = dkeys.shape[0]
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, d, jnp.int32)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        km = jnp.take(dkeys, jnp.clip(mid, 0, d - 1))
        r = km < q
        return jnp.where(r, mid + 1, lo), jnp.where(r, hi, mid)

    lo, hi = jax.lax.fori_loop(0, dsteps, body, (lo, hi))
    return lo


def _rmi_kernel(
    # refs, in order: q, stage0 params (w,b per layer), leaf arrays, keys, out
    *refs,
    hidden: Tuple[int, ...],
    n: int,
    num_leaves: int,
    steps: int,
):
    nl = len(hidden) + 1
    q_ref = refs[0]
    params = tuple(r[...] for r in refs[1 : 1 + 2 * nl])
    leaf_w_ref, leaf_b_ref, err_lo_ref, err_hi_ref, keys_ref = refs[
        1 + 2 * nl : 6 + 2 * nl
    ]
    out_ref = refs[-1]
    out_ref[...] = _base_lower_bound(
        q_ref[...], params, leaf_w_ref[...], leaf_b_ref[...],
        err_lo_ref[...], err_hi_ref[...], keys_ref[...],
        n=n, num_leaves=num_leaves, steps=steps,
    )


def _rmi_merged_kernel(
    # refs: q, stage0 params, leaf arrays, keys, delta keys, delta
    # prefix, out_base, out_merged
    *refs,
    hidden: Tuple[int, ...],
    n: int,
    num_leaves: int,
    steps: int,
    dsteps: int,
):
    nl = len(hidden) + 1
    q_ref = refs[0]
    params = tuple(r[...] for r in refs[1 : 1 + 2 * nl])
    (leaf_w_ref, leaf_b_ref, err_lo_ref, err_hi_ref, keys_ref,
     dkeys_ref, dprefix_ref) = refs[1 + 2 * nl : 8 + 2 * nl]
    base_ref, merged_ref = refs[-2], refs[-1]

    q = q_ref[...]
    lb = _base_lower_bound(
        q, params, leaf_w_ref[...], leaf_b_ref[...],
        err_lo_ref[...], err_hi_ref[...], keys_ref[...],
        n=n, num_leaves=num_leaves, steps=steps,
    )
    dlb = _delta_lower_bound(q, dkeys_ref[...], dsteps=dsteps)
    base_ref[...] = lb
    merged_ref[...] = lb + jnp.take(dprefix_ref[...], dlb)


def _array_lower_bound(
    arr: jnp.ndarray, q: jnp.ndarray, size, steps: int
) -> jnp.ndarray:
    """Branchless lower bound of each q in arr[0:size] (float or int
    arrays; fixed trip count so it lowers inside kernels).  Unlike the
    key-search loops, scan queries may equal or exceed every stored
    element (q = +inf sentinels, position queries past the pad), so the
    converged state is pinned with ``lo < hi`` — extra trips past
    convergence must not walk ``lo`` off the end."""

    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, size, jnp.int32)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        v = jnp.take(arr, jnp.clip(mid, 0, size - 1))
        r = (v < q) & (lo < hi)
        return jnp.where(r, mid + 1, lo), jnp.where(r, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _scan_page_body(
    t: jnp.ndarray,              # int32 target merged ranks (any shape)
    base_keys: jnp.ndarray,      # (N,) sorted normalized f32 base keys
    base_vals: jnp.ndarray,      # (N,) int32 payload aligned with base
    ins_keys: jnp.ndarray,       # (Di,) sorted eff. insert keys, +inf pad
    ins_vals: jnp.ndarray,       # (Di,) int32 staged values (0 on pads)
    del_pos: jnp.ndarray,        # (Dd,) sorted dead base positions, n pad
    end_rank: jnp.ndarray,       # () int32 — one past the last live rank
    *,
    steps: int,
    isteps: int,
    dsteps: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One merged row per target rank, without materializing the merge.

    The live merged array is A ∪ C with A = base minus the dead
    positions (``del_pos``) and C = the effective staged inserts —
    disjoint by construction (`delta.collapse_levels`), so every rank
    decomposes uniquely.  Per slot t:

      1. partition:  j = |{c ∈ C : merged_rank(c) < t}| by binary
         search on j over  merged_rank(C[j]) = j + a_before(C[j]),
         where a_before(x) = lower_bound(base, x) - dead_before;
      2. select:     the (t-j)-th live base row by binary search over
         base positions with live_before(p) = p - lower_bound(del_pos, p);
      3. emit        min(A[t-j], C[j]) with its source's value; slots
         at or past ``end_rank`` are masked dead (+inf key, 0 value).

    Fixed trip counts everywhere, so the same body lowers inside the
    Pallas kernel and the XLA fallback with bit-identical results.
    """
    inf = jnp.float32(jnp.inf)
    n = base_keys.shape[0]
    ni = ins_keys.shape[0]
    nd = del_pos.shape[0]

    # ---- partition: inserts among the first t merged rows -------------
    lo = jnp.zeros(t.shape, jnp.int32)
    hi = jnp.full(t.shape, ni, jnp.int32)

    def jbody(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        ck = jnp.take(ins_keys, jnp.clip(mid, 0, ni - 1))
        ck = jnp.where(mid >= ni, inf, ck)
        bl = _array_lower_bound(base_keys, ck, n, steps)
        dl = _array_lower_bound(del_pos, bl, nd, dsteps)
        pred = mid + (bl - dl) >= t
        adv = ~pred & (lo < hi)  # converged lanes stay pinned
        return jnp.where(adv, mid + 1, lo), jnp.where(pred, mid, hi)

    j, _ = jax.lax.fori_loop(0, isteps, jbody, (lo, hi))
    i = t - j

    # ---- select: the i-th live base position --------------------------
    lo = jnp.zeros(t.shape, jnp.int32)
    hi = jnp.full(t.shape, n, jnp.int32)

    def pbody(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        dl = _array_lower_bound(del_pos, mid + 1, nd, dsteps)
        pred = (mid + 1 - dl) >= (i + 1)
        adv = ~pred & (lo < hi)
        return jnp.where(adv, mid + 1, lo), jnp.where(pred, mid, hi)

    p, _ = jax.lax.fori_loop(0, steps, pbody, (lo, hi))

    a_key = jnp.where(p >= n, inf, jnp.take(base_keys, jnp.clip(p, 0, n - 1)))
    a_val = jnp.take(base_vals, jnp.clip(p, 0, n - 1))
    c_key = jnp.where(j >= ni, inf, jnp.take(ins_keys, jnp.clip(j, 0, ni - 1)))
    c_val = jnp.take(ins_vals, jnp.clip(j, 0, ni - 1))

    from_ins = c_key < a_key
    live = ((t >= 0) & (t < end_rank)).astype(jnp.int32)
    key = jnp.where(from_ins, c_key, a_key)
    val = jnp.where(from_ins, c_val, a_val)
    key = jnp.where(live == 1, key, inf)
    val = jnp.where(live == 1, val, 0)
    return key, val, live


def _scan_page_kernel(
    # refs: starts (1,), base_keys, base_vals, ins_keys, ins_vals,
    # del_pos, end_rank (1,), out_keys (1,P), out_vals, out_live
    starts_ref,
    base_keys_ref,
    base_vals_ref,
    ins_keys_ref,
    ins_vals_ref,
    del_pos_ref,
    end_ref,
    keys_out,
    vals_out,
    live_out,
    *,
    page_size: int,
    steps: int,
    isteps: int,
    dsteps: int,
):
    t = starts_ref[...][:, None] + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1
    )
    key, val, live = _scan_page_body(
        t, base_keys_ref[...], base_vals_ref[...], ins_keys_ref[...],
        ins_vals_ref[...], del_pos_ref[...], end_ref[0],
        steps=steps, isteps=isteps, dsteps=dsteps,
    )
    keys_out[...] = key
    vals_out[...] = val
    live_out[...] = live


@functools.partial(
    jax.jit, static_argnames=("page_size", "interpret")
)
def rmi_scan_page_pallas(
    starts: jax.Array,             # (G,) int32 page start ranks
    base_keys: jax.Array,          # (N,) sorted normalized f32
    base_vals: jax.Array,          # (N,) int32
    ins_keys: jax.Array,           # (Di,) +inf-padded eff. insert keys
    ins_vals: jax.Array,           # (Di,) int32
    del_pos: jax.Array,            # (Dd,) n-padded dead base positions
    end_rank: jax.Array,           # (1,) int32
    *,
    page_size: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-addressed merged scan gather: grid = pages, ONE pallas_call.

    Page g emits rows at merged ranks ``starts[g] + [0, page_size)`` as
    ``(keys f32, vals i32, live i32)`` — the streaming read path that
    follows a merged-rank lookup, with the same VMEM-residency argument
    as the lookup kernels (base + delta + one page tile).  No RMI here:
    ranks address the merge directly, so the kernel is three nested
    fixed-trip binary searches plus gathers, vectorized over the page.
    """
    interpret = _resolve_interpret(interpret)
    g = starts.shape[0]
    if g == 0:
        empty = jnp.zeros((0, page_size), jnp.int32)
        return empty.astype(jnp.float32), empty, empty
    steps = _search_steps(base_keys.shape[0])
    isteps = _search_steps(ins_keys.shape[0])
    dsteps = _search_steps(del_pos.shape[0])

    in_specs = [pl.BlockSpec((1,), lambda i: (i,))]
    in_specs += [_full_spec(a) for a in
                 (base_keys, base_vals, ins_keys, ins_vals, del_pos,
                  end_rank)]
    tile_spec = lambda: pl.BlockSpec((1, page_size), lambda i: (i, 0))
    keys, vals, live = pl.pallas_call(
        functools.partial(
            _scan_page_kernel, page_size=page_size, steps=steps,
            isteps=isteps, dsteps=dsteps,
        ),
        grid=(g,),
        in_specs=in_specs,
        out_specs=(tile_spec(), tile_spec(), tile_spec()),
        out_shape=(
            jax.ShapeDtypeStruct((g, page_size), jnp.float32),
            jax.ShapeDtypeStruct((g, page_size), jnp.int32),
            jax.ShapeDtypeStruct((g, page_size), jnp.int32),
        ),
        interpret=interpret,
    )(starts, base_keys, base_vals, ins_keys, ins_vals, del_pos, end_rank)
    return keys, vals, live


def _merged_rank_from_prefix(
    q: jnp.ndarray,              # f32 queries (any shape), normalized frame
    base_keys: jnp.ndarray,      # (N,) sorted f32, +inf past the true size
    live_prefix: jnp.ndarray,    # (N+1,) i32 live base rows below position p
    ins_keys: jnp.ndarray,       # (D,) sorted eff. insert keys, +inf pad
    *,
    steps: int,
    isteps: int,
) -> jnp.ndarray:
    """Merged lower-bound rank straight from the prefix-sum page index:

        rank(q) = live_prefix[lower_bound(base, q)] + lower_bound(ins, q)

    — the device-side twin of `PinnedView.rank`, so scan endpoints never
    round-trip through host NumPy.  ``live_prefix[p] = p - #tombstoned
    positions < p`` is precomputed host-side per (snapshot, delta)
    version; the two searches are fixed-trip and pad-safe (+inf pads
    sort past every finite query, `jnp.take` clamps)."""
    bl = _array_lower_bound(base_keys, q, base_keys.shape[0], steps)
    ins = _array_lower_bound(ins_keys, q, ins_keys.shape[0], isteps)
    return jnp.take(live_prefix, bl) + ins


def _scan_rows_from_index(
    t: jnp.ndarray,              # int32 target merged ranks (any shape)
    valid: jnp.ndarray,          # bool: lanes that hold a live row
    base_keys: jnp.ndarray,      # (N,) sorted f32, +inf past the true size
    base_vals: jnp.ndarray,      # (N,) int32 payload aligned with base
    live_prefix: jnp.ndarray,    # (N+1,) i32, pinned past the true size
    ins_keys: jnp.ndarray,       # (D,) sorted eff. insert keys, +inf pad
    ins_vals: jnp.ndarray,       # (D,) int32 staged values (0 on pads)
    ins_rank: jnp.ndarray,       # (D,) i32 merged rank of insert j, big pad
    *,
    psteps: int,
    msteps: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One merged row per target rank, resolved entirely through the
    precomputed prefix-sum page index — two single-gather fixed-trip
    searches per lane instead of `_scan_page_body`'s nested
    search-inside-search loops:

      1. partition:  j = lower_bound(ins_rank, t) — ``ins_rank[j] =
         j + live_base_before(ins[j])`` is the merged rank of staged
         insert j, strictly increasing, HOST-precomputed;
      2. select:     the (t-j)-th live base row via one lower bound
         over the monotone ``live_prefix`` array;
      3. emit        min(base row, insert row) with its source's value;
         lanes with ``valid`` False are masked dead (+inf key, 0 val).

    Decomposition identical to `_scan_page_body` (same j, same base
    position, same min rule), so rows match the NumPy merge oracle.
    """
    inf = jnp.float32(jnp.inf)
    n = base_keys.shape[0]
    ni = ins_keys.shape[0]

    j = _array_lower_bound(ins_rank, t, ni, msteps)
    a_i = t - j
    # smallest idx with live_prefix[idx] >= a_i + 1; row position idx-1
    p = _array_lower_bound(live_prefix, a_i + 1, n + 1, psteps) - 1

    a_key = jnp.where(
        (p < 0) | (p >= n), inf, jnp.take(base_keys, jnp.clip(p, 0, n - 1))
    )
    a_val = jnp.take(base_vals, jnp.clip(p, 0, n - 1))
    c_key = jnp.where(j >= ni, inf, jnp.take(ins_keys, jnp.clip(j, 0, ni - 1)))
    c_val = jnp.take(ins_vals, jnp.clip(j, 0, ni - 1))

    from_ins = c_key < a_key
    live = valid.astype(jnp.int32)
    key = jnp.where(from_ins, c_key, a_key)
    val = jnp.where(from_ins, c_val, a_val)
    key = jnp.where(live == 1, key, inf)
    val = jnp.where(live == 1, val, 0)
    return key, val, live


def _scan_range_kernel(
    # refs: bounds (2,), base_keys, base_vals, live_prefix, ins_keys,
    # ins_vals, ins_rank, out_keys (1,P), out_vals, out_live
    bounds_ref,
    base_keys_ref,
    base_vals_ref,
    live_prefix_ref,
    ins_keys_ref,
    ins_vals_ref,
    ins_rank_ref,
    keys_out,
    vals_out,
    live_out,
    *,
    page_size: int,
    steps: int,
    isteps: int,
    psteps: int,
    msteps: int,
):
    b = bounds_ref[...]
    r = _merged_rank_from_prefix(
        b, base_keys_ref[...], live_prefix_ref[...], ins_keys_ref[...],
        steps=steps, isteps=isteps,
    )
    r0 = r[0]
    r1 = jnp.maximum(r[1], r0)  # inverted ranges clamp empty
    g = pl.program_id(0)
    t = r0 + g * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1
    )
    key, val, live = _scan_rows_from_index(
        t, t < r1, base_keys_ref[...], base_vals_ref[...],
        live_prefix_ref[...], ins_keys_ref[...], ins_vals_ref[...],
        ins_rank_ref[...], psteps=psteps, msteps=msteps,
    )
    keys_out[...] = key
    vals_out[...] = val
    live_out[...] = live


@functools.partial(
    jax.jit, static_argnames=("page_size", "max_pages", "interpret")
)
def rmi_scan_range_pallas(
    bounds: jax.Array,             # (2,) f32 normalized [lo, hi)
    base_keys: jax.Array,          # (N,) sorted normalized f32
    base_vals: jax.Array,          # (N,) int32
    live_prefix: jax.Array,        # (N+1,) i32 prefix-sum page index
    ins_keys: jax.Array,           # (D,) +inf-padded eff. insert keys
    ins_vals: jax.Array,           # (D,) int32
    ins_rank: jax.Array,           # (D,) i32 merged rank of each insert
    *,
    page_size: int,
    max_pages: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused scan endpoints + page gather: ONE pallas_call computes the
    merged ranks ``(r0, r1)`` of [lo, hi) *and* streams every page of
    merged rows at ranks ``r0 + [0, r1 - r0)`` — no host rank
    round-trip between ranking and gathering.  Grid = pages
    (``max_pages`` is the caller's conservative static bound; pages
    past ``r1`` come back fully masked).  Rank-to-row resolution runs
    through the precomputed prefix-sum page index (`live_prefix`,
    ``ins_rank``), so each lane costs two single-gather fixed-trip
    searches — the nested tombstone searches of `rmi_scan_page_pallas`
    are hoisted to host precompute, amortized across every scan of a
    (snapshot, delta) version."""
    interpret = _resolve_interpret(interpret)
    g = max_pages
    steps = _search_steps(base_keys.shape[0])
    isteps = _search_steps(ins_keys.shape[0])
    psteps = _search_steps(base_keys.shape[0] + 1)
    msteps = _search_steps(ins_rank.shape[0])

    in_specs = [_full_spec(a) for a in
                (bounds, base_keys, base_vals, live_prefix, ins_keys,
                 ins_vals, ins_rank)]
    tile_spec = lambda: pl.BlockSpec((1, page_size), lambda i: (i, 0))
    keys, vals, live = pl.pallas_call(
        functools.partial(
            _scan_range_kernel, page_size=page_size, steps=steps,
            isteps=isteps, psteps=psteps, msteps=msteps,
        ),
        grid=(g,),
        in_specs=in_specs,
        out_specs=(tile_spec(), tile_spec(), tile_spec()),
        out_shape=(
            jax.ShapeDtypeStruct((g, page_size), jnp.float32),
            jax.ShapeDtypeStruct((g, page_size), jnp.int32),
            jax.ShapeDtypeStruct((g, page_size), jnp.int32),
        ),
        interpret=interpret,
    )(bounds, base_keys, base_vals, live_prefix, ins_keys, ins_vals,
      ins_rank)
    return keys, vals, live


def _sharded_scan_kernel(
    # refs: base (1,N), bvals (1,N), live_prefix (1,N+1), ins (1,D),
    # ivals (1,D), ins_rank (1,D), ls0 (1,), own_lo (1,), own_hi (1,),
    # out_keys (1,1,P), out_vals, out_live
    base_ref,
    bvals_ref,
    lp_ref,
    ins_ref,
    ivals_ref,
    irank_ref,
    ls0_ref,
    own_lo_ref,
    own_hi_ref,
    keys_out,
    vals_out,
    live_out,
    *,
    page_size: int,
    psteps: int,
    msteps: int,
):
    g = pl.program_id(1)
    t_rel = g * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1
    )
    own_lo, own_hi, ls0 = own_lo_ref[0], own_hi_ref[0], ls0_ref[0]
    owner = (t_rel >= own_lo) & (t_rel < own_hi)
    t_local = ls0 + t_rel - own_lo
    key, val, live = _scan_rows_from_index(
        t_local, owner, base_ref[0], bvals_ref[0], lp_ref[0],
        ins_ref[0], ivals_ref[0], irank_ref[0],
        psteps=psteps, msteps=msteps,
    )
    keys_out[0] = key
    vals_out[0] = val
    live_out[0] = live


@functools.partial(
    jax.jit, static_argnames=("page_size", "max_pages", "interpret")
)
def rmi_sharded_scan_page_pallas(
    base_keys: jax.Array,          # (S, N) sorted f32, +inf padded
    base_vals: jax.Array,          # (S, N) int32, 0 padded
    live_prefix: jax.Array,        # (S, N+1) i32, pinned past true n
    ins_keys: jax.Array,           # (S, D) +inf-padded eff. inserts
    ins_vals: jax.Array,           # (S, D) int32
    ins_rank: jax.Array,           # (S, D) i32, big pad
    ls0: jax.Array,                # (S,) i32 local rank of lo per shard
    own_lo: jax.Array,             # (S,) i32 shard's first output rank
    own_hi: jax.Array,             # (S,) i32 one past its last
    *,
    page_size: int,
    max_pages: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded stacked scan gather: grid = (shard, page), ONE
    pallas_call — the scan twin of `rmi_sharded_merged_lookup_pallas`.

    Shard ranges tile the key space, so the global page stream of
    [lo, hi) is the concatenation of per-shard sub-streams; ``own_lo``
    / ``own_hi`` (prefix sums of per-shard in-range spans, computed in
    the same jitted program by `ops.rmi_sharded_scan_page_op`'s rank
    pre-pass) say which slice of the output stream each shard owns.
    Every (shard, page) grid step resolves the page's target ranks
    against its own slab through the per-shard prefix-sum page index;
    non-owned lanes emit (+inf, 0, dead), so reducing min/sum/max over
    the shard axis reassembles the global pages.  Returns the raw
    (S, G, P) per-shard matrices; the op does the reduction."""
    interpret = _resolve_interpret(interpret)
    s = base_keys.shape[0]
    g = max_pages
    psteps = _search_steps(base_keys.shape[1] + 1)
    msteps = _search_steps(ins_rank.shape[1])

    def row_spec(a: jax.Array) -> pl.BlockSpec:
        return pl.BlockSpec(
            (1,) + a.shape[1:], lambda si, gi: (si,) + (0,) * (a.ndim - 1)
        )

    in_specs = [row_spec(a) for a in
                (base_keys, base_vals, live_prefix, ins_keys, ins_vals,
                 ins_rank, ls0, own_lo, own_hi)]
    tile_spec = lambda: pl.BlockSpec((1, 1, page_size),
                                     lambda si, gi: (si, gi, 0))
    keys, vals, live = pl.pallas_call(
        functools.partial(
            _sharded_scan_kernel, page_size=page_size, psteps=psteps,
            msteps=msteps,
        ),
        grid=(s, g),
        in_specs=in_specs,
        out_specs=(tile_spec(), tile_spec(), tile_spec()),
        out_shape=(
            jax.ShapeDtypeStruct((s, g, page_size), jnp.float32),
            jax.ShapeDtypeStruct((s, g, page_size), jnp.int32),
            jax.ShapeDtypeStruct((s, g, page_size), jnp.int32),
        ),
        interpret=interpret,
    )(base_keys, base_vals, live_prefix, ins_keys, ins_vals, ins_rank,
      ls0, own_lo, own_hi)
    return keys, vals, live


def _sharded_shard_body(
    q: jnp.ndarray,              # (B,) this shard's normalized queries
    params,                      # flat (w0, b0, ...) values for this shard
    leaf_w: jnp.ndarray,
    leaf_b: jnp.ndarray,
    err_lo: jnp.ndarray,
    err_hi: jnp.ndarray,
    keys: jnp.ndarray,           # (N,) padded; pads never read (clip by n)
    dkeys: jnp.ndarray,          # (D,) +inf-padded delta keys
    dprefix: jnp.ndarray,        # (D+1,) prefix, constant over the pad tail
    n,                           # () int32 — true base size of this shard
    m,                           # () int32 — true leaf count of this shard
    ratio,                       # () float32 — float32(m / n), HOST-computed
    *,
    steps: int,
    dsteps: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One shard of the sharded merged lookup: `_base_lower_bound` with
    the static (n, num_leaves) promoted to traced per-shard scalars, so
    heterogeneous shards stack on one axis (one kernel grid dim / one
    vmap axis) instead of one dispatch per shard.

    ``ratio`` must be ``np.float32(m / n)`` computed on the host — the
    same f64-divide-then-round the static kernel's weak-typed
    ``num_leaves / n`` python float performs — so leaf selection stays
    bit-identical to build-time leaf assignment (the window contract).
    ``steps`` is the max over shards; extra trips past a shard's own
    window only overshoot in the lb == n case, which the final
    ``minimum(lo, n)`` clamp repairs.  Returns ``(base_lb,
    delta_prefix_contribution)``; callers add the global shard offsets
    (see `ops.sharded_reassemble`).
    """
    nl = len(params) // 2
    h = q[:, None]
    for i in range(nl):
        w, b = params[2 * i], params[2 * i + 1]
        h = h @ w + b[None, :]
        if i < nl - 1:
            h = jnp.maximum(h, 0.0)
    p0 = h[:, 0]

    nf = n.astype(jnp.float32)
    leaf = jnp.clip(jnp.floor(p0 * ratio).astype(jnp.int32), 0, m - 1)
    slope = jnp.take(leaf_w, leaf)
    inter = jnp.take(leaf_b, leaf)
    pos = jnp.clip(slope * q + inter, 0.0, nf - 1.0)
    lo = jnp.clip((pos + jnp.take(err_lo, leaf)).astype(jnp.int32), 0, n)
    hi = jnp.clip((pos + jnp.take(err_hi, leaf)).astype(jnp.int32) + 1, 0, n)

    p0i = jnp.clip(pos.astype(jnp.int32), 0, n - 1)
    kp = jnp.take(keys, p0i)
    right = kp < q
    lo = jnp.where(right, jnp.maximum(lo, p0i + 1), lo)
    hi = jnp.where(right, hi, jnp.minimum(hi, p0i))

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        km = jnp.take(keys, jnp.clip(mid, 0, n - 1))
        r = km < q
        return jnp.where(r, mid + 1, lo), jnp.where(r, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    lb = jnp.minimum(lo, n)
    dlb = _delta_lower_bound(q, dkeys, dsteps=dsteps)
    return lb, jnp.take(dprefix, dlb)


def _rmi_sharded_kernel(
    # refs: q (1,bq), stage0 params (1,...), leaf arrays (1,M), keys
    # (1,N), dkeys (1,D), dprefix (1,D+1), n (1,), m (1,), ratio (1,),
    # out_base (1,bq), out_contrib (1,bq)
    *refs,
    hidden: Tuple[int, ...],
    steps: int,
    dsteps: int,
):
    nl = len(hidden) + 1
    q_ref = refs[0]
    params = tuple(r[0] for r in refs[1 : 1 + 2 * nl])
    (leaf_w_ref, leaf_b_ref, err_lo_ref, err_hi_ref, keys_ref,
     dkeys_ref, dprefix_ref, n_ref, m_ref, ratio_ref) = refs[
        1 + 2 * nl : 11 + 2 * nl
    ]
    base_ref, contrib_ref = refs[-2], refs[-1]
    lb, contrib = _sharded_shard_body(
        q_ref[0], params, leaf_w_ref[0], leaf_b_ref[0],
        err_lo_ref[0], err_hi_ref[0], keys_ref[0],
        dkeys_ref[0], dprefix_ref[0],
        n_ref[0], m_ref[0], ratio_ref[0],
        steps=steps, dsteps=dsteps,
    )
    base_ref[0, :] = lb
    contrib_ref[0, :] = contrib


def _tile(b: int, block_q: int) -> Tuple[int, int]:
    bq = min(block_q, b)
    padded = (b + bq - 1) // bq * bq
    return bq, padded


def _full_spec(a: jax.Array) -> pl.BlockSpec:
    return pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)


@functools.partial(
    jax.jit,
    static_argnames=("hidden", "n", "num_leaves", "max_window", "block_q", "interpret"),
)
def rmi_lookup_pallas(
    q: jax.Array,                      # (B,) normalized queries
    stage0: Tuple[jax.Array, ...],     # (w0, b0, w1, b1, ...) flattened
    leaf_w: jax.Array,                 # (M,)
    leaf_b: jax.Array,                 # (M,)
    err_lo: jax.Array,                 # (M,)
    err_hi: jax.Array,                 # (M,)
    sorted_keys: jax.Array,            # (N,)
    *,
    hidden: Tuple[int, ...],
    n: int,
    num_leaves: int,
    max_window: int,
    block_q: int = 1024,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = _resolve_interpret(interpret)
    b = q.shape[0]
    if b == 0:  # degenerate batch: nothing to tile
        return jnp.zeros((0,), jnp.int32)
    bq, padded = _tile(b, block_q)
    if padded != b:
        q = jnp.pad(q, (0, padded - b))
    steps = _search_steps(max_window)
    grid = (padded // bq,)

    in_specs = [pl.BlockSpec((bq,), lambda i: (i,))]
    in_specs += [_full_spec(p) for p in stage0]
    in_specs += [_full_spec(leaf_w), _full_spec(leaf_b),
                 _full_spec(err_lo), _full_spec(err_hi)]
    in_specs += [_full_spec(sorted_keys)]

    out = pl.pallas_call(
        functools.partial(
            _rmi_kernel, hidden=hidden, n=n, num_leaves=num_leaves, steps=steps
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int32),
        interpret=interpret,
    )(q, *stage0, leaf_w, leaf_b, err_lo, err_hi, sorted_keys)
    return out[:b]


@functools.partial(
    jax.jit,
    static_argnames=("hidden", "n", "num_leaves", "max_window", "block_q", "interpret"),
)
def rmi_merged_lookup_pallas(
    q: jax.Array,                      # (B,) normalized queries
    stage0: Tuple[jax.Array, ...],     # (w0, b0, w1, b1, ...) flattened
    leaf_w: jax.Array,                 # (M,)
    leaf_b: jax.Array,                 # (M,)
    err_lo: jax.Array,                 # (M,)
    err_hi: jax.Array,                 # (M,)
    sorted_keys: jax.Array,            # (N,)
    delta_keys: jax.Array,             # (D,) +inf-padded pow2 (combine_for_device)
    delta_prefix: jax.Array,           # (D+1,) int32 net +1/-1 prefix
    *,
    hidden: Tuple[int, ...],
    n: int,
    num_leaves: int,
    max_window: int,
    block_q: int = 1024,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused base+delta merged lookup: one kernel, two outputs.

    Returns ``(base_lb, merged_rank)`` — the RMI lower bound in the
    base array plus the merged rank after the staged delta's +1/-1
    prefix contribution.  Retraces per (index, delta capacity bucket):
    ``delta_keys`` comes +inf-padded to a power of two, so the jit
    cache is keyed by bucket, never by individual writes.
    """
    interpret = _resolve_interpret(interpret)
    b = q.shape[0]
    if b == 0:  # degenerate batch: nothing to tile
        empty = jnp.zeros((0,), jnp.int32)
        return empty, empty
    bq, padded = _tile(b, block_q)
    if padded != b:
        q = jnp.pad(q, (0, padded - b))
    steps = _search_steps(max_window)
    dsteps = _search_steps(delta_keys.shape[0])
    grid = (padded // bq,)

    in_specs = [pl.BlockSpec((bq,), lambda i: (i,))]
    in_specs += [_full_spec(p) for p in stage0]
    in_specs += [_full_spec(leaf_w), _full_spec(leaf_b),
                 _full_spec(err_lo), _full_spec(err_hi)]
    in_specs += [_full_spec(sorted_keys), _full_spec(delta_keys),
                 _full_spec(delta_prefix)]

    tile_spec = lambda: pl.BlockSpec((bq,), lambda i: (i,))
    base, merged = pl.pallas_call(
        functools.partial(
            _rmi_merged_kernel, hidden=hidden, n=n, num_leaves=num_leaves,
            steps=steps, dsteps=dsteps,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(tile_spec(), tile_spec()),
        out_shape=(
            jax.ShapeDtypeStruct((padded,), jnp.int32),
            jax.ShapeDtypeStruct((padded,), jnp.int32),
        ),
        interpret=interpret,
    )(q, *stage0, leaf_w, leaf_b, err_lo, err_hi, sorted_keys,
      delta_keys, delta_prefix)
    return base[:b], merged[:b]


@functools.partial(
    jax.jit,
    static_argnames=("hidden", "max_window", "block_q", "interpret"),
)
def rmi_sharded_merged_lookup_pallas(
    q: jax.Array,                      # (S, B) per-shard normalized queries
    stage0: Tuple[jax.Array, ...],     # (w0, b0, ...) each stacked (S, ...)
    leaf_w: jax.Array,                 # (S, M) zero-padded past each shard's m
    leaf_b: jax.Array,                 # (S, M)
    err_lo: jax.Array,                 # (S, M)
    err_hi: jax.Array,                 # (S, M)
    sorted_keys: jax.Array,            # (S, N) padded; pads unread (clip by n)
    delta_keys: jax.Array,             # (S, D) +inf-padded per-shard deltas
    delta_prefix: jax.Array,           # (S, D+1) prefix, constant on pad tail
    shard_n: jax.Array,                # (S,) int32 true base sizes
    shard_m: jax.Array,                # (S,) int32 true leaf counts
    shard_ratio: jax.Array,            # (S,) float32 — f32(m/n) per shard
    *,
    hidden: Tuple[int, ...],
    max_window: int,                   # max over shards (extra trips clamped)
    block_q: int = 1024,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sharded merged lookup: grid = (shard, query tile), ONE pallas_call.

    Every query tile is evaluated on every shard row (the shard axis is
    a grid dimension — on TPU it maps onto cores/devices; there is no
    data-dependent per-shard gather inside the kernel).  Returns the
    per-shard local ``(base_lb, delta_prefix_contribution)`` matrices,
    both (S, B); `ops.sharded_reassemble` selects each query's routed
    row and adds the global prefix-sum offsets.  Static shapes are the
    padded maxima — per-shard true sizes travel as traced scalars, so
    one jit cache entry serves heterogeneous shards.
    """
    interpret = _resolve_interpret(interpret)
    s, b = q.shape
    if b == 0:
        empty = jnp.zeros((s, 0), jnp.int32)
        return empty, empty
    bq, padded = _tile(b, block_q)
    if padded != b:
        q = jnp.pad(q, ((0, 0), (0, padded - b)))
    steps = _search_steps(max_window)
    dsteps = _search_steps(delta_keys.shape[1])
    grid = (s, padded // bq)

    def row_spec(a: jax.Array) -> pl.BlockSpec:
        return pl.BlockSpec((1,) + a.shape[1:], lambda si, ti: (si,) + (0,) * (a.ndim - 1))

    in_specs = [pl.BlockSpec((1, bq), lambda si, ti: (si, ti))]
    in_specs += [row_spec(p) for p in stage0]
    in_specs += [row_spec(a) for a in
                 (leaf_w, leaf_b, err_lo, err_hi, sorted_keys,
                  delta_keys, delta_prefix, shard_n, shard_m, shard_ratio)]

    tile_spec = lambda: pl.BlockSpec((1, bq), lambda si, ti: (si, ti))
    base, contrib = pl.pallas_call(
        functools.partial(
            _rmi_sharded_kernel, hidden=hidden, steps=steps, dsteps=dsteps
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(tile_spec(), tile_spec()),
        out_shape=(
            jax.ShapeDtypeStruct((s, padded), jnp.int32),
            jax.ShapeDtypeStruct((s, padded), jnp.int32),
        ),
        interpret=interpret,
    )(q, *stage0, leaf_w, leaf_b, err_lo, err_hi, sorted_keys,
      delta_keys, delta_prefix, shard_n, shard_m, shard_ratio)
    return base[:, :b], contrib[:, :b]


def stage0_flat(params: Dict[str, np.ndarray]) -> Tuple[jax.Array, ...]:
    """RMIndex.stage0_params dict -> ordered (w0, b0, w1, b1, ...) tuple."""
    nl = len(params) // 2
    out = []
    for i in range(nl):
        out.append(jnp.asarray(params[f"w{i}"]))
        out.append(jnp.asarray(params[f"b{i}"]))
    return tuple(out)
