"""CLI: ``python -m tools.lixlint [paths...]``.

Exit status 0 iff every finding is either waived in-source or present in
the committed baseline (``tools/lixlint/baseline.json``).  New findings
print with file:line and fail the run — fix them, waive them with a
reason, or (for pre-existing debt only) re-baseline with
``--write-baseline`` and justify the diff in review.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from . import PASSES, run_passes
from .core import Baseline, Finding, load_sources

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lixlint",
        description="repo-aware static analysis (lock/dispatch/purity passes)",
    )
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to analyze (default: src/repro)")
    ap.add_argument("--root", default=".",
                    help="repo root findings paths are relative to")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {','.join(PASSES)}")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline JSON path")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to the current finding set")
    ap.add_argument("--report", default=None,
                    help="write a machine-readable findings report (JSON)")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()
    paths = [Path(p) if Path(p).is_absolute() else root / p for p in args.paths]
    sources = load_sources(paths, root)
    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    findings = run_passes(sources, passes)

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        Baseline().save(baseline_path, findings)
        print(f"lixlint: wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = Baseline() if args.no_baseline else Baseline.load(baseline_path)
    new, baselined, stale = baseline.split(findings)

    if args.report:
        payload = {
            "files": len(sources),
            "passes": passes,
            "new": [vars(f) | {"key": f.key} for f in new],
            "baselined": [vars(f) | {"key": f.key} for f in baselined],
            "stale_baseline_keys": stale,
        }
        Path(args.report).write_text(json.dumps(payload, indent=2) + "\n")

    for f in new:
        print(f.render())
    if stale:
        print(
            f"lixlint: note: {len(stale)} stale baseline entr"
            f"{'y' if len(stale) == 1 else 'ies'} (fixed findings — "
            f"shrink the baseline):", file=sys.stderr,
        )
        for key in stale:
            print(f"  {key}", file=sys.stderr)
    summary = (
        f"lixlint: {len(sources)} files, {len(new)} new finding(s), "
        f"{len(baselined)} baselined"
    )
    print(summary)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
