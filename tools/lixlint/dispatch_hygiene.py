"""Pass 2: dispatch hygiene — static twin of ``tests/test_dispatch_count.py``.

Walks a static call graph from the hot-read entry points
(``lookup_batch`` / ``get`` / ``contains`` / ``scan_batch`` on both
services, frontend ``pump``) and flags host round-trips inside any
reachable function:

  * ``.item()``, ``.block_until_ready()``, ``jax.device_get(...)`` —
    always findings on the hot path;
  * ``np.asarray`` / ``np.array`` / ``float`` / ``int`` / ``bool``
    applied to a *device-tainted* local — a hidden device->host sync.

Taint is intra-procedural and name-based: locals assigned from device
producers (``jnp.*``, ``jax.*``, anything named ``*_op`` / ``*_pallas``,
or calling a local bound from a ``*_fn`` factory) are tainted, and taint
propagates through assignments that mention a tainted name.  Function
boundaries deliberately launder taint — every function's *returned*
hygiene is its own responsibility, which keeps the analysis local and
the findings explainable.

Call resolution is over-approximate: ``self.m()`` binds within the
enclosing class first; ``anything.m()`` fans out to every analyzed class
defining ``m``; bare ``f()`` binds to module-level functions of the
analyzed set.  Write/maintenance sinks (insert/delete/compaction/
rebalance/save/load and model (re)fits) are cut — host work is the
design there, and ``pump`` would otherwise drag the whole compactor in.

Intentional syncs (e.g. the one documented f64 rank-refinement read-back
in ``_ranks``) carry ``# lixlint: host-sync(<reason>)`` waivers.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, SourceFile

PASS_ID = "dispatch"

# (class name, method) roots — the same set tests/test_dispatch_count.py
# pins dynamically (plus frontend pump, which coalesces onto them).
DEFAULT_ENTRY_POINTS: Tuple[Tuple[str, str], ...] = (
    ("IndexService", "lookup_batch"),
    ("IndexService", "get"),
    ("IndexService", "contains"),
    ("IndexService", "scan_batch"),
    ("ShardedIndexService", "lookup_batch"),
    ("ShardedIndexService", "get"),
    ("ShardedIndexService", "contains"),
    ("ShardedIndexService", "scan_batch"),
    ("IndexFrontend", "pump"),
)

# Method names never traversed: write/maintenance paths where host work
# is by design, plus (re)training.
STOP_METHODS: Set[str] = {
    "insert", "delete", "maybe_compact", "flush", "save", "load",
    "rebalance", "compact", "checkpoint", "restore", "fit", "refit",
    "train", "build_snapshot", "execute", "build_rmi", "refit_rmi",
}

# jax.* members that return host metadata, not device arrays
_JAX_HOST_META = {
    "devices", "local_devices", "device_count", "local_device_count",
    "default_backend", "ShapeDtypeStruct", "eval_shape", "named_scope",
}

_SYNC_METHODS = {"item", "block_until_ready"}
_HOST_COERCIONS = {"float", "int", "bool"}
_NP_SINKS = {"asarray", "array", "copy", "ascontiguousarray"}
_TAINT_SUFFIXES = ("_op", "_pallas")
_FN_FACTORY_SUFFIX = "_fn"


def _attr_chain(node: ast.AST) -> List[str]:
    """``a.b.c`` -> ["a", "b", "c"]; non-chains -> []."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


@dataclass(frozen=True)
class FuncKey:
    """Stable identity of an analyzed function."""

    rel: str
    qualname: str  # "Class.method" or "function"


class ProjectIndex:
    """Classes, methods, and module functions across the analyzed set."""

    def __init__(self, sources: Sequence[SourceFile]) -> None:
        self.functions: Dict[FuncKey, Tuple[SourceFile, ast.AST]] = {}
        self.by_method: Dict[str, List[FuncKey]] = {}
        self.by_class_method: Dict[Tuple[str, str], List[FuncKey]] = {}
        self.by_module_fn: Dict[str, List[FuncKey]] = {}
        for src in sources:
            for node in src.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    key = FuncKey(src.rel, node.name)
                    self.functions[key] = (src, node)
                    self.by_module_fn.setdefault(node.name, []).append(key)
            for cls in ast.walk(src.tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                for node in cls.body:
                    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        key = FuncKey(src.rel, f"{cls.name}.{node.name}")
                        self.functions[key] = (src, node)
                        self.by_method.setdefault(node.name, []).append(key)
                        self.by_class_method.setdefault(
                            (cls.name, node.name), []
                        ).append(key)

    def resolve(self, cls: Optional[str], name: str, on_self: bool) -> List[FuncKey]:
        if name in STOP_METHODS:
            return []
        if on_self and cls is not None:
            keys = self.by_class_method.get((cls, name))
            if keys:
                return keys
        out = list(self.by_method.get(name, ()))
        if not on_self:
            out.extend(self.by_module_fn.get(name, ()))
        elif not out:
            out.extend(self.by_module_fn.get(name, ()))
        return out


class _FuncScanner(ast.NodeVisitor):
    """Taint + flag + outgoing-edge scan of one function body."""

    def __init__(self, src: SourceFile, key: FuncKey, index: ProjectIndex,
                 findings: List[Finding]) -> None:
        self.src = src
        self.key = key
        self.cls = key.qualname.split(".")[0] if "." in key.qualname else None
        self.index = index
        self.findings = findings
        self.tainted: Set[str] = set()
        self.tainted_fns: Set[str] = set()
        self.edges: List[FuncKey] = []
        self.stmt_stack: List[ast.stmt] = []

    # -- infra ----------------------------------------------------------

    def visit(self, node: ast.AST) -> None:
        is_stmt = isinstance(node, ast.stmt)
        if is_stmt:
            self.stmt_stack.append(node)
        try:
            super().visit(node)
        finally:
            if is_stmt:
                self.stmt_stack.pop()

    def _context_lines(self, node: ast.AST) -> List[int]:
        lines = list(self.src.node_lines(node))
        if self.stmt_stack:
            lines.extend(self.src.node_lines(self.stmt_stack[-1]))
        return lines

    def _emit(self, node: ast.AST, code: str, msg: str) -> None:
        if self.src.waived(PASS_ID, self._context_lines(node)):
            return
        snippet = ast.unparse(node)
        if len(snippet) > 60:
            snippet = snippet[:57] + "..."
        self.findings.append(
            Finding(
                PASS_ID, self.src.rel, node.lineno, code,
                f"{self.key.qualname}:{snippet}",
                f"in {self.key.qualname} (hot read path): {msg}",
            )
        )

    # -- taint ----------------------------------------------------------

    def _is_device_call(self, call: ast.Call) -> bool:
        chain = _attr_chain(call.func)
        if not chain:
            return False
        if chain[0] in ("jnp", "jax") and chain[-1] not in (
            {"device_get"} | _JAX_HOST_META
        ):
            return True
        last = chain[-1]
        if any(last.endswith(sfx) for sfx in _TAINT_SUFFIXES):
            return True
        if len(chain) == 1 and chain[0] in self.tainted_fns:
            return True
        return False

    def _is_fn_factory_call(self, call: ast.Call) -> bool:
        chain = _attr_chain(call.func)
        return bool(chain) and chain[-1].endswith(_FN_FACTORY_SUFFIX)

    def _mentions_taint(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in self.tainted:
                return True
            if isinstance(sub, ast.Call) and self._is_device_call(sub):
                return True
        return False

    def _taint_targets(self, targets: Sequence[ast.AST]) -> None:
        for t in targets:
            if isinstance(t, ast.Name):
                self.tainted.add(t.id)
            elif isinstance(t, (ast.Tuple, ast.List)):
                self._taint_targets(t.elts)
            elif isinstance(t, ast.Starred):
                self._taint_targets([t.value])

    def visit_Assign(self, node: ast.Assign) -> None:
        value = node.value
        if isinstance(value, ast.Call) and self._is_fn_factory_call(value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.tainted_fns.add(t.id)
        if self._mentions_taint(value):
            self._taint_targets(node.targets)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self._mentions_taint(node.value):
            self._taint_targets([node.target])
        self.generic_visit(node)

    # -- flags + edges ---------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        chain = _attr_chain(func)
        # 1. unconditional syncs
        if isinstance(func, ast.Attribute) and func.attr in _SYNC_METHODS:
            self._emit(
                node, "host-sync",
                f"`.{func.attr}()` forces a device->host sync",
            )
        if chain and chain[0] == "jax" and chain[-1] == "device_get":
            self._emit(node, "host-sync", "`jax.device_get` on the hot path")
        # 2. host coercions of tainted values
        args_tainted = any(self._mentions_taint(a) for a in node.args)
        if isinstance(func, ast.Name) and func.id in _HOST_COERCIONS and args_tainted:
            self._emit(
                node, "host-coercion",
                f"`{func.id}(...)` over a device value blocks on transfer",
            )
        if (
            len(chain) == 2 and chain[0] in ("np", "numpy")
            and chain[1] in _NP_SINKS and args_tainted
        ):
            self._emit(
                node, "host-transfer",
                f"`np.{chain[1]}` over a device value is a hidden "
                f"device->host copy",
            )
        # 3. call-graph edges
        if isinstance(func, ast.Name):
            self.edges.extend(self.index.resolve(self.cls, func.id, on_self=False))
        elif isinstance(func, ast.Attribute):
            on_self = isinstance(func.value, ast.Name) and func.value.id == "self"
            self.edges.extend(self.index.resolve(
                self.cls if on_self else None, func.attr, on_self=on_self,
            ))
            func._lix_call_func = True  # type: ignore[attr-defined]
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        # Bare method references used as callbacks: self.service.get
        # passed into _apply_keyed and called there as op(...).  Skip
        # attributes that are the func of a Call (handled above).
        if (
            isinstance(node.ctx, ast.Load)
            and not getattr(node, "_lix_call_func", False)
            and node.attr in self.index.by_method
            and node.attr not in STOP_METHODS
        ):
            self.edges.extend(self.index.resolve(None, node.attr, on_self=False))
        self.generic_visit(node)


def run(
    sources: Sequence[SourceFile],
    entry_points: Sequence[Tuple[str, str]] = DEFAULT_ENTRY_POINTS,
) -> List[Finding]:
    index = ProjectIndex(sources)
    src_by_rel = {s.rel: s for s in sources}
    worklist: List[FuncKey] = []
    for cls, meth in entry_points:
        worklist.extend(index.by_class_method.get((cls, meth), ()))
    seen: Set[FuncKey] = set()
    findings: List[Finding] = []
    while worklist:
        key = worklist.pop()
        if key in seen:
            continue
        seen.add(key)
        src, node = index.functions[key]
        scanner = _FuncScanner(src_by_rel[src.rel], key, index, findings)
        for stmt in node.body:  # type: ignore[attr-defined]
            scanner.visit(stmt)
        worklist.extend(scanner.edges)
    return findings


def reachable(
    sources: Sequence[SourceFile],
    entry_points: Sequence[Tuple[str, str]] = DEFAULT_ENTRY_POINTS,
) -> Set[str]:
    """Qualnames reachable from the entry points (for coverage tests)."""
    index = ProjectIndex(sources)
    worklist: List[FuncKey] = []
    for cls, meth in entry_points:
        worklist.extend(index.by_class_method.get((cls, meth), ()))
    seen: Set[FuncKey] = set()
    findings: List[Finding] = []
    src_by_rel = {s.rel: s for s in sources}
    while worklist:
        key = worklist.pop()
        if key in seen:
            continue
        seen.add(key)
        src, node = index.functions[key]
        scanner = _FuncScanner(src_by_rel[src.rel], key, index, findings)
        for stmt in node.body:  # type: ignore[attr-defined]
            scanner.visit(stmt)
        worklist.extend(scanner.edges)
    return {k.qualname for k in seen}
