"""Pass 1: lock discipline / race detector.

A class opts into analysis by (any of):

  * declaring guarded state — ``self._x = ...  # guarded-by: _lock``
  * spawning threads (``threading.Thread(...)`` anywhere in its body)
  * carrying a class-level ``# lixlint: thread-shared`` marker

For opted-in classes the pass enforces:

  * every load/store of a guarded attribute happens under a syntactic
    ``with self.<lock>:`` for the declared lock, inside ``__init__``,
    under a ``# lixlint: holds(<lock>)`` contract, or behind a waiver
    (``unguarded-access``);
  * a thread-spawning / thread-shared class that mutates state after
    construction declares at least one lock (``threading.Lock/RLock/
    Condition`` or ``obs.lockstat.make_lock``) or a class-level
    ``unsynchronized`` waiver (``no-lock``); immutable-after-init
    classes pass without a lock but keep the store check;
  * attribute *stores* outside ``__init__`` — even to undeclared attrs —
    happen under some declared lock or a waiver (``unguarded-write``),
    because publishing new state to concurrent readers without a fence
    is exactly the bug class this pass exists for.

Purely syntactic by design: it cannot see aliasing (``svc = self``) or
cross-object locking, which is what ``holds(...)`` and the waivers are
for.  The runtime half (lock *order*) lives in ``repro.obs.lockstat``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, SourceFile

PASS_ID = "lock"

_INIT_METHODS = {"__init__", "__post_init__", "__new__"}
_LOCK_FACTORIES = {
    "Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore", "make_lock",
}


def _call_name(call: ast.Call) -> Optional[str]:
    """Trailing name of the called thing: ``a.b.C()`` -> ``C``."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _def_header_lines(fn: ast.AST) -> range:
    """Lines of the signature + decorators (where function-level
    directives live), excluding the body."""
    assert isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
    if isinstance(fn, ast.Lambda):
        return range(fn.lineno, (fn.end_lineno or fn.lineno) + 1)
    start = fn.lineno
    for dec in fn.decorator_list:
        start = min(start, dec.lineno)
    body_start = fn.body[0].lineno
    return range(start, body_start + 1)


class _ClassInfo:
    def __init__(self, src: SourceFile, node: ast.ClassDef) -> None:
        self.src = src
        self.node = node
        self.name = node.name
        self.guarded: Dict[str, str] = {}       # attr -> lock name
        self.locks: Set[str] = set()            # declared lock attrs
        self.spawns_threads = False
        self.methods: List[ast.AST] = []
        self._collect()

    def mutates_after_init(self) -> bool:
        """True if any non-init method stores to a ``self.`` attribute.
        A shared class that never does is immutable-after-construction
        and needs no lock (the store check still applies, so a future
        mutation re-arms the ``no-lock`` requirement)."""
        for method in self.methods:
            if getattr(method, "name", "") in _INIT_METHODS:
                continue
            for sub in ast.walk(method):
                if isinstance(sub, ast.Attribute) and _self_attr(sub):
                    if isinstance(sub.ctx, (ast.Store, ast.Del)):
                        return True
        return False

    def _collect(self) -> None:
        for stmt in self.node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.methods.append(stmt)
        for sub in ast.walk(self.node):
            # guarded-by declarations and lock factories on any
            # `self.x = ...` statement in the class body
            if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                targets = sub.targets if isinstance(sub, ast.Assign) else [sub.target]
                attrs = [a for a in (_self_attr(t) for t in targets) if a]
                if attrs:
                    for line in self.src.node_lines(sub):
                        lock = self.src.guarded_decl(line)
                        if lock:
                            for a in attrs:
                                self.guarded[a] = lock
                            break
                    value = sub.value
                    if isinstance(value, ast.Call):
                        name = _call_name(value)
                        if name in _LOCK_FACTORIES:
                            self.locks.update(attrs)
                        # Condition(make_lock(...)) etc.
                        for arg in value.args:
                            if (
                                isinstance(arg, ast.Call)
                                and _call_name(arg) in _LOCK_FACTORIES
                            ):
                                self.locks.update(attrs)
            if isinstance(sub, ast.Call) and _call_name(sub) == "Thread":
                self.spawns_threads = True

    def method_line_ranges(self) -> List[range]:
        out = []
        for m in self.methods:
            start = m.lineno
            for dec in getattr(m, "decorator_list", ()):
                start = min(start, dec.lineno)
            out.append(range(start, (m.end_lineno or m.lineno) + 1))
        return out

    def class_level_lines(self) -> List[int]:
        """Lines inside the class body but outside every method."""
        body = self.method_line_ranges()
        out = []
        for line in self.src.node_lines(self.node):
            if not any(line in r for r in body):
                out.append(line)
        return out


class _MethodChecker(ast.NodeVisitor):
    """Walk one method, tracking held locks and enclosing statements."""

    def __init__(
        self,
        info: _ClassInfo,
        method: ast.AST,
        findings: List[Finding],
        in_init: bool,
        check_stores: bool,
    ) -> None:
        self.info = info
        self.src = info.src
        self.findings = findings
        self.in_init = in_init
        self.check_stores = check_stores
        self.method_name = getattr(method, "name", "<lambda>")
        self.held: List[str] = []
        self.holds_stack: List[Set[str]] = [
            self.src.holds_locks(_def_header_lines(method))
        ]
        self.stmt_stack: List[ast.stmt] = []

    # -- helpers --------------------------------------------------------

    def _context_lines(self, node: ast.AST) -> List[int]:
        lines = list(self.src.node_lines(node))
        if self.stmt_stack:
            lines.extend(self.src.node_lines(self.stmt_stack[-1]))
        return lines

    def _lock_satisfied(self, lock: str, node: ast.AST) -> bool:
        if lock in self.held:
            return True
        for holds in self.holds_stack:
            if lock in holds:
                return True
        if lock in self.src.holds_locks(self._context_lines(node)):
            return True
        return False

    def _any_lock_held(self, node: ast.AST) -> bool:
        if self.held:
            return True
        if any(h for h in self.holds_stack):
            return True
        return bool(self.src.holds_locks(self._context_lines(node)))

    def _waived(self, node: ast.AST) -> bool:
        return self.src.waived(PASS_ID, self._context_lines(node))

    def _emit(self, node: ast.AST, code: str, detail: str, msg: str) -> None:
        self.findings.append(
            Finding(PASS_ID, self.src.rel, node.lineno, code, detail, msg)
        )

    # -- traversal ------------------------------------------------------

    def visit(self, node: ast.AST) -> None:
        is_stmt = isinstance(node, ast.stmt)
        if is_stmt:
            self.stmt_stack.append(node)
        try:
            super().visit(node)
        finally:
            if is_stmt:
                self.stmt_stack.pop()

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            ctx = item.context_expr
            attr = _self_attr(ctx)
            if attr is None and isinstance(ctx, ast.Call):
                attr = _self_attr(ctx.func)  # e.g. self._lock.acquire_timeout()
            if attr is not None:
                acquired.append(attr)
        for item in node.items:
            self.visit(item)
        self.held.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        for _ in acquired:
            self.held.pop()

    def _visit_nested_fn(self, node: ast.AST) -> None:
        # A nested def/lambda does not inherit the syntactic with-scope:
        # it may run later on another thread.  It keeps holds() from its
        # own header only.
        saved_held, self.held = self.held, []
        self.holds_stack.append(self.src.holds_locks(_def_header_lines(node)))
        self.generic_visit(node)
        self.holds_stack.pop()
        self.held = saved_held

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_nested_fn(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_nested_fn(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_nested_fn(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is None or self.in_init:
            self.generic_visit(node)
            return
        info = self.info
        detail = f"{info.name}.{self.method_name}:{attr}"
        if attr in info.guarded:
            lock = info.guarded[attr]
            if not self._lock_satisfied(lock, node) and not self._waived(node):
                kind = "write" if isinstance(node.ctx, (ast.Store, ast.Del)) else "read"
                self._emit(
                    node, "unguarded-access", detail,
                    f"{kind} of guarded attribute self.{attr} outside "
                    f"`with self.{lock}` (declared `# guarded-by: {lock}`)",
                )
        elif (
            self.check_stores
            and isinstance(node.ctx, (ast.Store, ast.Del))
            and attr not in info.locks
        ):
            if not self._any_lock_held(node) and not self._waived(node):
                self._emit(
                    node, "unguarded-write", detail,
                    f"store to self.{attr} outside any declared lock in a "
                    f"thread-shared class (declare `# guarded-by:`, hold a "
                    f"lock, or waive with `# lixlint: unsynchronized(...)`)",
                )
        self.generic_visit(node)


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = _ClassInfo(src, node)
            class_lines = info.class_level_lines()
            class_directives = {d.name for d in src.directives_on(class_lines)}
            class_waived = src.waived(PASS_ID, class_lines)
            marked_shared = "thread-shared" in class_directives
            shared = info.spawns_threads or marked_shared
            analyzed = shared or bool(info.guarded)
            if not analyzed:
                continue
            if class_waived:
                continue
            if shared and not info.locks and info.mutates_after_init():
                findings.append(
                    Finding(
                        PASS_ID, src.rel, node.lineno, "no-lock",
                        f"{info.name}",
                        f"class {info.name} "
                        + ("spawns threads" if info.spawns_threads
                           else "is marked thread-shared")
                        + " but declares no lock (threading.Lock/RLock/"
                          "Condition or lockstat.make_lock) and no "
                          "class-level `# lixlint: unsynchronized(...)` waiver",
                    )
                )
            # Only structurally-shared classes get the unannotated-store
            # check; guarded-only classes are checked for their guarded
            # attrs alone.
            for method in info.methods:
                in_init = getattr(method, "name", "") in _INIT_METHODS
                checker = _MethodChecker(info, method, findings, in_init, shared)
                for stmt in method.body:  # type: ignore[attr-defined]
                    checker.visit(stmt)
    return findings
