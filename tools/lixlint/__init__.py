"""lixlint: repo-aware static analysis for the learned-index stack.

Four AST passes (lock discipline, dispatch hygiene, trace purity,
fault-wall accountability) plus a shared annotation/waiver/baseline
layer; the runtime lock-order sanitizer lives in
``repro.obs.lockstat``.  Run as::

    python -m tools.lixlint src/repro

See the README "Static analysis" section for the annotation grammar.
"""

from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from . import dispatch_hygiene, fault_walls, lock_discipline, trace_purity
from .core import Baseline, Finding, SourceFile, load_sources

__all__ = [
    "Baseline",
    "Finding",
    "SourceFile",
    "load_sources",
    "run_passes",
    "lock_discipline",
    "dispatch_hygiene",
    "trace_purity",
    "fault_walls",
]

PASSES = ("lock", "dispatch", "purity", "faultwall")


def run_passes(
    sources: Sequence[SourceFile],
    passes: Sequence[str] = PASSES,
    entry_points: Optional[Sequence[Tuple[str, str]]] = None,
) -> List[Finding]:
    """Run the requested passes; returns unwaived findings (sorted)."""
    findings: List[Finding] = []
    for src in sources:
        findings.extend(src.malformed)
    if "lock" in passes:
        findings.extend(lock_discipline.run(sources))
    if "dispatch" in passes:
        if entry_points is None:
            findings.extend(dispatch_hygiene.run(sources))
        else:
            findings.extend(dispatch_hygiene.run(sources, entry_points))
    if "purity" in passes:
        findings.extend(trace_purity.run(sources))
    if "faultwall" in passes:
        findings.extend(fault_walls.run(sources))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def analyze_paths(
    paths: Sequence[str],
    root: Optional[Path] = None,
    passes: Sequence[str] = PASSES,
) -> List[Finding]:
    """Convenience: load every .py under `paths` and run `passes`."""
    root = root or Path.cwd()
    sources = load_sources([Path(p) for p in paths], root)
    return run_passes(sources, passes)
