"""Clean twin of dispatch_bad.py: same entry points, zero findings."""

import jax.numpy as jnp
import numpy as np


class FixtureService:
    def lookup_batch(self, keys):
        q = jnp.asarray(keys)
        return jnp.searchsorted(self._keys, q)  # stays on device

    def get(self, key):
        pos = jnp.searchsorted(self._keys, jnp.asarray(key))
        # lixlint: host-sync(designed single read-back for exact refinement)
        return int(pos)

    def contains(self, key):
        n = int(np.asarray([1, 2, 3]).size)  # host array: never traced
        return jnp.any(jnp.equal(self._keys, key)), n

    def scan_batch(self, lo, hi):
        return jnp.arange(lo, hi)

    def _locate(self, key):
        return jnp.searchsorted(self._keys, jnp.asarray(key))


class FixtureFrontend:
    def pump(self):
        return jnp.ones((4,)) * 2.0
