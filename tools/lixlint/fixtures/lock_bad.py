"""Seeded lock-discipline violations: every marked line MUST be caught
(tests/test_lixlint.py asserts the exact set)."""

import threading


class RacyCounter:  # spawns a thread -> opted into analysis
    def __init__(self):
        self._lock = threading.RLock()
        self._count = 0  # guarded-by: _lock
        self._worker = None

    def start(self):
        self._worker = threading.Thread(target=self.bump)
        self._worker.start()

    def bump(self):
        self._count += 1  # VIOLATION: unguarded-access (write, no lock)

    def peek(self):
        return self._count  # VIOLATION: unguarded-access (read, no lock)

    def publish(self, x):
        self.latest = x  # VIOLATION: unguarded-write (unannotated store)


class NoLockPool:  # VIOLATION: no-lock (mutates state, declares no lock)
    # lixlint: thread-shared
    def __init__(self):
        self.items = []

    def put(self, x):
        self.items = self.items + [x]  # VIOLATION: unguarded-write


class StaleWaiver:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0  # guarded-by: _lock

    def touch(self):
        # VIOLATION below: waiver-missing-reason (bare waiver, no rationale)
        # lixlint: unsynchronized
        self._n += 1
