"""Clean twin of faultwall_bad.py: every wall says what it contains."""


def contained(fn):
    try:
        return fn()
    except BaseException:  # fault-wall: probe — failure is the answer
        return None


def contained_above(fn):
    try:
        return fn()
    # fault-wall: per-request isolation — the error lands on the request
    except BaseException as e:
        return e


def narrow(fn):
    try:
        return fn()
    except ValueError:  # narrow excepts need no directive
        return None


class Dispatcher:
    def round(self, reqs):
        out = []
        for r in reqs:
            try:
                out.append(r())
            except BaseException as e:  # fault-wall: one crash must not kill the round
                out.append(e)
        return out
