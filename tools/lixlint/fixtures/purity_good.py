"""Clean twin of purity_bad.py: static shape math, f32, no host state."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def clean_kernel(x_ref, o_ref, *, block):
    if block > 8:  # legal: kwonly kernel args are static by construction
        o_ref[...] = x_ref[...] * jnp.float32(2.0)
    else:
        o_ref[...] = x_ref[...]


def run_clean(x):
    return pl.pallas_call(
        functools.partial(clean_kernel, block=8),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


@jax.jit
def shape_branch(x, lo):
    if x.shape[0] > 4:  # legal: shape reads are static
        return x - lo
    return jnp.where(lo > 0, x - lo, x)


@functools.partial(jax.jit, static_argnames=("mode",))
def moded(x, mode):
    if mode == "fast":  # legal: static_argnames operand
        return x * 2.0
    return x
