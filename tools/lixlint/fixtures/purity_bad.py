"""Seeded trace-purity violations in a pallas kernel and a jit fn."""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def leaky_kernel(x_ref, o_ref, *, block):
    t = time.perf_counter()  # VIOLATION: impure-host-call (clock)
    noise = np.random.rand()  # VIOLATION: impure-host-call (RNG)
    o_ref[...] = x_ref[...].astype(jnp.float64) + t + noise  # VIOLATION: f64


def run_leaky(x):
    return pl.pallas_call(
        functools.partial(leaky_kernel, block=8),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


@jax.jit
def branchy(x, lo):
    if lo > 0:  # VIOLATION: trace-branch on traced operand
        return x - lo
    return x
