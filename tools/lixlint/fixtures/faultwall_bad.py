"""Seeded fault-wall violations: unexplained BaseException walls."""


def swallow_everything(fn):
    try:
        return fn()
    except BaseException:  # VIOLATION: no fault-wall reason
        return None


def naked(fn):
    try:
        return fn()
    except:  # noqa: E722  VIOLATION: naked except is a wall too
        return None


class Dispatcher:
    def round(self, reqs):
        out = []
        for r in reqs:
            try:
                out.append(r())
            except (ValueError, BaseException) as e:  # VIOLATION: tupled wall
                out.append(e)
        return out
