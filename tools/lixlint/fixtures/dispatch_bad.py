"""Seeded dispatch-hygiene violations reachable from FixtureService
read entry points (tests pass entry_points=[("FixtureService", ...)])."""

import jax
import jax.numpy as jnp
import numpy as np


class FixtureService:
    def lookup_batch(self, keys):
        q = jnp.asarray(keys)
        pos = jnp.searchsorted(self._keys, q)
        return int(pos[0])  # VIOLATION: host-coercion on traced value

    def get(self, key):
        pos = self._locate(key)
        return pos.item()  # VIOLATION: host-sync .item()

    def contains(self, key):
        mask = jnp.equal(self._keys, key)
        host = np.asarray(mask)  # VIOLATION: host-transfer np.asarray
        return bool(host.any())

    def scan_batch(self, lo, hi):
        vals = jnp.arange(lo, hi)
        vals.block_until_ready()  # VIOLATION: host-sync barrier
        return vals

    def _locate(self, key):
        return jnp.searchsorted(self._keys, jnp.asarray(key))

    def insert(self, key):  # STOP method: never traversed
        arr = jnp.asarray(key)
        return arr.item()  # not a finding: write path may sync


def helper_transfer(x):
    y = jnp.abs(x)
    return jax.device_get(y)  # VIOLATION: host-transfer (via pump call)


class FixtureFrontend:
    def pump(self):
        return helper_transfer(jnp.ones((4,)))
