"""Clean twin of lock_bad.py: same shapes, zero findings."""

import threading


class SafeCounter:
    def __init__(self):
        self._lock = threading.RLock()
        self._count = 0  # guarded-by: _lock
        self._worker = None

    def start(self):
        with self._lock:
            self._worker = threading.Thread(target=self.bump)
            self._worker.start()

    def bump(self):
        with self._lock:
            self._count += 1

    def peek(self):
        with self._lock:
            return self._count

    def publish(self, x):
        # lixlint: unsynchronized(single benchmark thread owns this slot)
        self.latest = x

    def _drain(self):  # lixlint: holds(_lock)
        self._count = 0  # legal: caller contract asserts the lock


class FrozenPool:
    # immutable after construction: no lock required, store check active
    # lixlint: thread-shared
    def __init__(self):
        self.items = ()

    def get(self, i):
        return self.items[i]
