"""Pass 3: jit/pallas trace purity.

Scopes: (a) Pallas kernel bodies — any local function passed (directly
or via ``functools.partial``) as the first argument to ``pallas_call``;
(b) jit-closed functions — decorated ``@jax.jit`` or
``@functools.partial(jax.jit, static_argnames=...)``, or rebound via
``f = jax.jit(g)``.

Inside those, the pass flags:

  * host clock / RNG calls (``time.*``, ``np.random.*``, ``random.*``) —
    they execute once at trace time and freeze into the program
    (``impure-host-call``);
  * f64 markers (``np.float64`` / ``jnp.float64`` / ``"float64"`` /
    ``dtype="double"``) — the device plane is f32 by contract, exact
    rank math is host f64; mixing them on device is this repo's
    most-repeated bug class (``f64-on-device``);
  * Python ``if`` / ``while`` whose test reads a *traced* parameter
    directly — a concretization error waiting for non-interpret mode
    (``trace-branch``).  Parameters named in ``static_argnames`` and
    ``.shape`` / ``.dtype`` / ``.ndim`` / ``.size`` attribute reads are
    static and exempt.

Waive intentional deviations with ``# lixlint: impure(<reason>)``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, SourceFile

PASS_ID = "purity"

_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}
_HOST_MODULE_CALLS = {
    ("time",): "host clock read",
    ("np", "random"): "host RNG",
    ("numpy", "random"): "host RNG",
    ("random",): "host RNG",
}


def _attr_chain(node: ast.AST) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


def _local_functions(tree: ast.Module) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def _static_argnames(call: ast.Call) -> Set[str]:
    """Constant names in a ``static_argnames=(...)`` keyword."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    out.add(sub.value)
    return out


def _jit_static_names(fn: ast.AST) -> Optional[Set[str]]:
    """If `fn` is jit-decorated, the static argnames; else None."""
    for dec in getattr(fn, "decorator_list", ()):
        chain = _attr_chain(dec)
        if chain[-2:] == ["jax", "jit"] or chain == ["jit"]:
            return set()
        if isinstance(dec, ast.Call):
            fchain = _attr_chain(dec.func)
            if fchain[-1:] == ["jit"]:
                return _static_argnames(dec)
            if fchain[-1:] == ["partial"]:
                if dec.args and _attr_chain(dec.args[0])[-1:] == ["jit"]:
                    return _static_argnames(dec)
    return None


def _kernel_fn_names(tree: ast.Module) -> Set[str]:
    """Names of functions passed (possibly via partial) to pallas_call."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain[-1:] != ["pallas_call"]:
            continue
        if not node.args:
            continue
        first = node.args[0]
        if isinstance(first, ast.Name):
            out.add(first.id)
        elif isinstance(first, ast.Call):
            fchain = _attr_chain(first.func)
            if fchain[-1:] == ["partial"] and first.args:
                inner = first.args[0]
                if isinstance(inner, ast.Name):
                    out.add(inner.id)
    return out


def _jit_rebinds(tree: ast.Module) -> Set[str]:
    """Function names rebound through ``x = jax.jit(f)`` (or partial)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        v = node.value
        if not isinstance(v, ast.Call):
            continue
        chain = _attr_chain(v.func)
        target = None
        if chain[-1:] == ["jit"] and v.args:
            target = v.args[0]
        elif chain[-1:] == ["partial"] and v.args:
            if _attr_chain(v.args[0])[-1:] == ["jit"] and len(v.args) > 1:
                target = v.args[1]
        if isinstance(target, ast.Name):
            out.add(target.id)
    return out


class _PurityChecker(ast.NodeVisitor):
    def __init__(
        self,
        src: SourceFile,
        fn: ast.AST,
        kind: str,
        static_names: Set[str],
        findings: List[Finding],
    ) -> None:
        self.src = src
        self.fn = fn
        self.kind = kind  # "kernel" | "jit"
        self.name = getattr(fn, "name", "<fn>")
        self.findings = findings
        self.stmt_stack: List[ast.stmt] = []
        args = fn.args  # type: ignore[attr-defined]
        all_args = list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
        self.traced_params = {
            a.arg for a in all_args if a.arg not in static_names and a.arg != "self"
        }

    def visit(self, node: ast.AST) -> None:
        is_stmt = isinstance(node, ast.stmt)
        if is_stmt:
            self.stmt_stack.append(node)
        try:
            super().visit(node)
        finally:
            if is_stmt:
                self.stmt_stack.pop()

    def _context_lines(self, node: ast.AST) -> List[int]:
        lines = list(self.src.node_lines(node))
        if self.stmt_stack:
            lines.extend(self.src.node_lines(self.stmt_stack[-1]))
        return lines

    def _emit(self, node: ast.AST, code: str, msg: str) -> None:
        if self.src.waived(PASS_ID, self._context_lines(node)):
            return
        snippet = ast.unparse(node)
        if len(snippet) > 60:
            snippet = snippet[:57] + "..."
        self.findings.append(
            Finding(
                PASS_ID, self.src.rel, node.lineno, code,
                f"{self.name}:{snippet}",
                f"in {self.kind} fn {self.name}: {msg}",
            )
        )

    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if len(chain) >= 2:
            for prefix, what in _HOST_MODULE_CALLS.items():
                if tuple(chain[: len(prefix)]) == prefix and len(chain) > len(prefix):
                    self._emit(
                        node, "impure-host-call",
                        f"{what} `{'.'.join(chain)}` executes at trace "
                        f"time and freezes into the compiled program",
                    )
                    break
        self.generic_visit(node)

    def _check_f64(self, node: ast.AST) -> None:
        chain = _attr_chain(node)
        if chain[-1:] == ["float64"] or chain[-1:] == ["double"]:
            self._emit(
                node, "f64-on-device",
                "f64 on the device plane (f32 by contract; exact rank "
                "math is host-side f64)",
            )

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._check_f64(node)
        self.generic_visit(node)

    def visit_Constant(self, node: ast.Constant) -> None:
        if node.value == "float64" or node.value == "double":
            self._emit(
                node, "f64-on-device",
                "f64 dtype string on the device plane (f32 by contract)",
            )

    def _check_branch(self, test: ast.expr, node: ast.stmt, kw: str) -> None:
        for sub in ast.walk(test):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
                # neutralize `param.shape...` subtrees: mark names under
                # a static attribute access as safe
                for inner in ast.walk(sub.value):
                    if isinstance(inner, ast.Name):
                        inner._lix_static = True  # type: ignore[attr-defined]
        for sub in ast.walk(test):
            if (
                isinstance(sub, ast.Name)
                and sub.id in self.traced_params
                and not getattr(sub, "_lix_static", False)
            ):
                self._emit(
                    node, "trace-branch",
                    f"Python `{kw}` on traced operand `{sub.id}` "
                    f"concretizes the tracer (use jnp.where / lax.cond)",
                )
                return

    def visit_If(self, node: ast.If) -> None:
        self._check_branch(node.test, node, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_branch(node.test, node, "while")
        self.generic_visit(node)


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        local = _local_functions(src.tree)
        kernel_names = _kernel_fn_names(src.tree)
        rebinds = _jit_rebinds(src.tree)
        seen: Set[int] = set()
        for name, fn in local.items():
            static = _jit_static_names(fn)
            kind = None
            static_names: Set[str] = set()
            if name in kernel_names:
                kind = "kernel"
                # keyword-only args of a pallas kernel come from
                # functools.partial closure -> static by construction
                static_names = {
                    a.arg for a in fn.args.kwonlyargs  # type: ignore[attr-defined]
                }
            elif static is not None:
                kind, static_names = "jit", static
            elif name in rebinds:
                kind, static_names = "jit", set()
            if kind is None or id(fn) in seen:
                continue
            seen.add(id(fn))
            checker = _PurityChecker(src, fn, kind, static_names, findings)
            for stmt in fn.body:  # type: ignore[attr-defined]
                checker.visit(stmt)
    return findings
