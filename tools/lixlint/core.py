"""lixlint core: source model, annotations, waivers, findings, baseline.

The analyzer is comment-driven, and Python's ``ast`` drops comments, so
each :class:`SourceFile` keeps a per-line comment map scraped from the
raw source next to the parsed tree.  Annotation grammar (documented in
the README "Static analysis" section):

  ``# guarded-by: _lock``
      On an attribute-assignment line in ``__init__``: every read/write
      of that attribute outside ``with self._lock`` is a finding.
  ``# lixlint: thread-shared``
      Class-level marker: opt the class into shared-state analysis even
      if it never spawns a thread itself (instances are handed to other
      threads).
  ``# lixlint: holds(_lock)``
      On a ``def`` line (or any statement line): the enclosing code runs
      with ``_lock`` held by caller contract, so guarded accesses under
      it are legal.
  ``# lixlint: unsynchronized(<reason>)``
      Lock-discipline waiver (line-, function- or class-level).
  ``# lixlint: host-sync(<reason>)``
      Dispatch-hygiene waiver: this host round-trip is intentional.
  ``# lixlint: impure(<reason>)``
      Trace-purity waiver.
  ``# lixlint: ignore(<reason>)``
      Suppress every pass on the line.

Waivers carry a mandatory reason: a bare ``unsynchronized`` without
``(...)`` is itself reported (``waiver-missing-reason``) so the escape
hatch stays auditable.

Baseline entries match findings by stable key (pass:path:code:detail),
never by line number, so unrelated edits don't churn the baseline.
"""

from __future__ import annotations

import ast
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "SourceFile",
    "load_sources",
    "Baseline",
    "GUARDED_RE",
    "DIRECTIVE_RE",
]

# ``# guarded-by: _lock``  (also accepts ``# guarded by:``)
GUARDED_RE = re.compile(r"#\s*guarded[- ]by:\s*(?P<lock>[A-Za-z_][A-Za-z0-9_]*)")

# ``# lixlint: directive(arg)[, directive2(arg2) ...]``
DIRECTIVE_RE = re.compile(r"#\s*lixlint:\s*(?P<body>.+)$")
_DIRECTIVE_ITEM_RE = re.compile(
    r"(?P<name>[a-z-]+)\s*(?:\(\s*(?P<arg>[^()]*)\s*\))?"
)

# Directives that waive a pass; maps directive name -> pass id it waives
# (``ignore`` waives everything).
WAIVER_PASSES = {
    "unsynchronized": "lock",
    "host-sync": "dispatch",
    "impure": "purity",
    "ignore": "*",
}
# Directives that carry semantics rather than waiving.
MARKER_DIRECTIVES = {"thread-shared", "holds"}


@dataclass(frozen=True)
class Directive:
    name: str
    arg: Optional[str]
    line: int


@dataclass(frozen=True)
class Finding:
    """One analyzer finding.

    ``detail`` is a line-number-free symbol path (e.g.
    ``ShardedIndexService.insert:_shards``) used as the stable baseline
    key; ``line`` is for humans.
    """

    pass_id: str
    path: str
    line: int
    code: str
    detail: str
    message: str

    @property
    def key(self) -> str:
        return f"{self.pass_id}:{self.path}:{self.code}:{self.detail}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_id}/{self.code}] {self.message}"


class SourceFile:
    """A parsed module plus its comment/directive maps."""

    def __init__(self, path: Path, root: Path) -> None:
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        # line -> full comment text (comments only, via tokenize so '#'
        # inside string literals never parses as an annotation)
        self.comments: Dict[int, str] = {}
        self._scan_comments()
        # line -> [Directive]
        self.directives: Dict[int, List[Directive]] = {}
        self.malformed: List[Finding] = []
        self._parse_directives()
        self._attach_standalone()

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(iter(self.text.splitlines(True)).__next__)
            for tok in tokens:
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:  # pragma: no cover - parse already succeeded
            for i, line in enumerate(self.lines, start=1):
                if "#" in line:
                    self.comments[i] = line[line.index("#"):]

    def _parse_directives(self) -> None:
        for line, comment in self.comments.items():
            m = DIRECTIVE_RE.search(comment)
            if not m:
                continue
            body = m.group("body")
            for item in _DIRECTIVE_ITEM_RE.finditer(body):
                name = item.group("name")
                if name not in WAIVER_PASSES and name not in MARKER_DIRECTIVES:
                    self.malformed.append(
                        Finding(
                            "meta", self.rel, line, "unknown-directive",
                            f"L{name}",
                            f"unknown lixlint directive {name!r}",
                        )
                    )
                    continue
                arg = item.group("arg")
                if arg is not None:
                    arg = arg.strip()
                if name in WAIVER_PASSES and not arg:
                    self.malformed.append(
                        Finding(
                            "meta", self.rel, line, "waiver-missing-reason",
                            f"L{line}:{name}",
                            f"waiver {name!r} requires a reason: "
                            f"# lixlint: {name}(<why>)",
                        )
                    )
                    continue
                self.directives.setdefault(line, []).append(Directive(name, arg, line))

    def _attach_standalone(self) -> None:
        # A directive on its own comment line governs the next code line
        # (standard standalone-pragma semantics), so long waiver reasons
        # don't have to fit on the statement line.
        for line in sorted(self.directives):
            if line > len(self.lines):
                continue
            if not self.lines[line - 1].lstrip().startswith("#"):
                continue
            nxt = line + 1
            while nxt <= len(self.lines):
                s = self.lines[nxt - 1].strip()
                if s and not s.startswith("#"):
                    break
                nxt += 1
            if nxt <= len(self.lines):
                for d in self.directives[line]:
                    self.directives.setdefault(nxt, []).append(d)

    # -- queries --------------------------------------------------------

    def guarded_decl(self, line: int) -> Optional[str]:
        """Lock name declared by a ``# guarded-by:`` comment on `line`."""
        comment = self.comments.get(line)
        if not comment:
            return None
        m = GUARDED_RE.search(comment)
        return m.group("lock") if m else None

    def directives_on(self, lines: Iterable[int]) -> List[Directive]:
        out: List[Directive] = []
        for line in lines:
            out.extend(self.directives.get(line, ()))
        return out

    def node_lines(self, node: ast.AST) -> range:
        lineno = getattr(node, "lineno", None)
        if lineno is None:
            return range(0)
        end = getattr(node, "end_lineno", None) or lineno
        return range(lineno, end + 1)

    def waived(self, pass_id: str, lines: Iterable[int]) -> bool:
        """True if any line carries a waiver for `pass_id` (or ignore)."""
        for d in self.directives_on(lines):
            waives = WAIVER_PASSES.get(d.name)
            if waives == "*" or waives == pass_id:
                return True
        return False

    def holds_locks(self, lines: Iterable[int]) -> Set[str]:
        """Lock names asserted held via ``holds(...)`` on any of `lines`."""
        out: Set[str] = set()
        for d in self.directives_on(lines):
            if d.name == "holds" and d.arg:
                for part in d.arg.split(","):
                    part = part.strip()
                    if part:
                        out.add(part)
        return out


def load_sources(paths: Sequence[Path], root: Path) -> List[SourceFile]:
    """Load every ``.py`` under `paths` (files or directories)."""
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    seen: Set[Path] = set()
    out: List[SourceFile] = []
    for f in files:
        f = f.resolve()
        if f in seen:
            continue
        seen.add(f)
        out.append(SourceFile(f, root))
    return out


@dataclass
class Baseline:
    """Committed findings ledger: keys the gate tolerates (legacy debt)."""

    entries: Dict[str, str] = field(default_factory=dict)  # key -> note

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        raw = json.loads(path.read_text())
        entries: Dict[str, str] = {}
        for item in raw.get("findings", []):
            entries[item["key"]] = item.get("note", "")
        return cls(entries)

    def save(self, path: Path, findings: Sequence[Finding]) -> None:
        payload = {
            "comment": "lixlint baseline: pre-existing findings tolerated by the "
            "CI gate. Shrink this file; never grow it without review.",
            "findings": [
                {"key": f.key, "message": f.message} for f in
                sorted(findings, key=lambda f: f.key)
            ],
        }
        path.write_text(json.dumps(payload, indent=2) + "\n")

    def split(
        self, findings: Sequence[Finding]
    ) -> Tuple[List[Finding], List[Finding], List[str]]:
        """Partition into (new, baselined) + stale baseline keys."""
        new: List[Finding] = []
        old: List[Finding] = []
        hit: Set[str] = set()
        for f in findings:
            if f.key in self.entries:
                old.append(f)
                hit.add(f.key)
            else:
                new.append(f)
        stale = sorted(k for k in self.entries if k not in hit)
        return new, old, stale
