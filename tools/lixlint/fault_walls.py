"""Pass 4: fault-wall accountability.

A bare ``except BaseException`` (or a naked ``except:``) is this
repo's strongest containment construct: it swallows *everything*,
including injected faults, ``KeyboardInterrupt`` and ``SystemExit``.
The serving and supervision layers use such walls deliberately — one
request's crash must not kill the dispatcher, one merge crash must not
kill the compactor — but an *unexplained* wall is indistinguishable
from a bug that eats errors.

So every wall must say what it contains: a ``# fault-wall: <reason>``
comment on the ``except`` line itself or on the comment line directly
above it.  Handlers that catch ``BaseException`` inside a tuple are
walls too.  Findings: ``unannotated-fault-wall``.

``# lixlint: ignore(<reason>)`` waives, as everywhere; prefer the
``fault-wall:`` directive — it documents rather than silences.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Sequence, Tuple

from .core import Finding, SourceFile

PASS_ID = "faultwall"

FAULT_WALL_RE = re.compile(r"fault[- ]wall\s*:")


def _is_wall(expr: object) -> bool:
    """True if the except clause catches BaseException (or everything)."""
    if expr is None:  # naked ``except:``
        return True
    if isinstance(expr, ast.Name):
        return expr.id == "BaseException"
    if isinstance(expr, ast.Attribute):
        return expr.attr == "BaseException"
    if isinstance(expr, ast.Tuple):
        return any(_is_wall(e) for e in expr.elts)
    return False


def _walls(tree: ast.Module) -> List[Tuple[str, ast.ExceptHandler]]:
    """(enclosing qualname, handler) for every fault wall, in order."""
    out: List[Tuple[str, ast.ExceptHandler]] = []

    def visit(node: ast.AST, qual: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                visit(child, f"{qual}.{child.name}" if qual else child.name)
                continue
            if isinstance(child, ast.ExceptHandler) and _is_wall(child.type):
                out.append((qual or "<module>", child))
            visit(child, qual)

    visit(tree, "")
    return out


def _annotated(src: SourceFile, line: int) -> bool:
    for ln in (line, line - 1):
        comment = src.comments.get(ln)
        if comment and FAULT_WALL_RE.search(comment):
            # a directly-preceding comment only governs this handler if
            # it is a standalone comment line (not trailing other code)
            if ln == line or src.lines[ln - 1].lstrip().startswith("#"):
                return True
    return False


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        ordinals: Dict[str, int] = {}
        for qual, handler in _walls(src.tree):
            ordinals[qual] = ordinals.get(qual, 0) + 1
            if _annotated(src, handler.lineno):
                continue
            if src.waived(PASS_ID, src.node_lines(handler)):
                continue
            detail = f"{qual}:wall#{ordinals[qual]}"
            findings.append(Finding(
                PASS_ID, src.rel, handler.lineno, "unannotated-fault-wall",
                detail,
                f"{qual}: bare BaseException wall without a "
                "'# fault-wall: <reason>' comment — say what it contains "
                "(or narrow the except)",
            ))
    return findings
