"""Repo tooling namespace (static analysis, CI helpers)."""
